"""Sharded-vs-single-device parity: ``make_sharded_train_step`` on a
``make_debug_mesh(2, 2)`` (8 forced host devices, 4 used) must reproduce
the unsharded ``train_step`` — params and metrics within tolerance, with
the input ``TrainState`` donated — for both optimizers and grad-accum
settings. Subprocess so the XLA_FLAGS device-count override never leaks
into other tests."""
import json
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.config import ModelConfig, RLConfig, TrainConfig, ATTN, MLP
    from repro.models import init_params
    from repro.parallel import ExecutionPlan, make_debug_mesh, \\
        make_sharded_train_step
    from repro.training import init_state, train_step

    TINY = ModelConfig(name="tiny", family="dense", num_layers=2,
                       d_model=48, num_heads=4, num_kv_heads=2, d_ff=96,
                       vocab_size=32, block_pattern=(ATTN,),
                       ffn_pattern=(MLP,), dtype="float32",
                       attn_impl="naive", remat=False, rope_theta=1e4)
    rl = RLConfig(loss_type="gepo", group_size=4, beta_kl=0.005)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (8, 10), 0, 32),
        "mask": jnp.ones((8, 9)),
        "sampler_lp": -jnp.abs(jax.random.normal(ks[1], (8, 9))),
        "rewards": (jax.random.uniform(ks[2], (8,)) > 0.5).astype(
            jnp.float32),
    }
    params = init_params(TINY, ks[3])
    plan = ExecutionPlan(mesh=make_debug_mesh(2, 2), mode="train")
    assert plan.num_devices == 4

    results = {}
    for optimizer in ("adamw", "adafactor"):
        for accum in (1, 2):
            tc = TrainConfig(learning_rate=1e-3, grad_accum=accum,
                             total_steps=10)
            # single-device reference (no plan, no jit-boundary sharding)
            ref_state = init_state(TINY, tc, params, optimizer=optimizer)
            ref_new, ref_m = train_step(TINY, rl, tc, ref_state, batch,
                                        optimizer=optimizer)
            # sharded run on the 2x2 mesh, donated TrainState
            st = init_state(TINY, tc, params, optimizer=optimizer,
                            plan=plan)
            step = make_sharded_train_step(TINY, rl, tc, plan,
                                           optimizer=optimizer)
            new_state, m = step(st, plan.device_put_batch(TINY, batch))
            # donation: the input buffers must be consumed, not copied
            donated = all(l.is_deleted() for l in
                          jax.tree_util.tree_leaves(st.params))
            # params parity
            max_err = 0.0
            for a, b in zip(jax.tree_util.tree_leaves(ref_new.params),
                            jax.tree_util.tree_leaves(new_state.params)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=5e-4, atol=1e-5)
                max_err = max(max_err, float(np.max(np.abs(
                    np.asarray(a) - np.asarray(b)))))
            # metrics parity
            for k in ref_m:
                np.testing.assert_allclose(
                    float(ref_m[k]), float(m[k]), rtol=2e-3, atol=1e-5,
                    err_msg=f"{optimizer}/accum{accum}/{k}")
            # out shardings honour the plan (params sharded, not bounced
            # back to a single device)
            lead = jax.tree_util.tree_leaves(new_state.params)[0]
            assert lead.sharding.mesh == plan.mesh
            results[f"{optimizer}_accum{accum}"] = {
                "donated": donated, "max_param_err": max_err}
            assert donated, (optimizer, accum)
    print(json.dumps({"ok": True, "results": results}))
""")


def test_sharded_step_matches_single_device():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-4000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"]
    assert set(rec["results"]) == {"adamw_accum1", "adamw_accum2",
                                   "adafactor_accum1", "adafactor_accum2"}
