"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles in ``repro.kernels.ref`` (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tols(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("shape", [
        (1, 128, 4, 4, 32), (2, 256, 8, 2, 64), (1, 512, 4, 1, 64),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_sweep(self, rng, shape, dtype):
        b, s, hq, hkv, d = shape
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (b, s, hq, d), dtype)
        k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
        v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
        out = ops.flash_attention(q, k, v, causal=True, block_q=64,
                                  block_k=64, interpret=True)
        expect = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32),
            **_tols(dtype))

    @pytest.mark.parametrize("window", [64, 128])
    def test_sliding_window(self, rng, window):
        b, s, hq, hkv, d = 2, 256, 4, 2, 32
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (b, s, hq, d))
        k = jax.random.normal(ks[1], (b, s, hkv, d))
        v = jax.random.normal(ks[2], (b, s, hkv, d))
        out = ops.flash_attention(q, k, v, causal=True, window=window,
                                  block_q=64, block_k=64, interpret=True)
        expect = ref.flash_attention_ref(q, k, v, causal=True,
                                         window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-5, atol=2e-5)

    def test_softcap_and_bidir(self, rng):
        b, s, hq, hkv, d = 1, 128, 4, 4, 32
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (b, s, hq, d))
        k = jax.random.normal(ks[1], (b, s, hkv, d))
        v = jax.random.normal(ks[2], (b, s, hkv, d))
        for causal, cap in [(True, 30.0), (False, None)]:
            out = ops.flash_attention(q, k, v, causal=causal, softcap=cap,
                                      block_q=32, block_k=32,
                                      interpret=True)
            expect = ref.flash_attention_ref(q, k, v, causal=causal,
                                             softcap=cap)
            np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                       rtol=2e-5, atol=2e-5)


class TestFlashAttentionModelWiring:
    """Satellites: ``models/attention.py::attention`` reaches the Pallas
    kernel behind ``impl='pallas'``, and the kernel's block sizes shrink
    to fitting divisors instead of asserting on non-multiple shapes."""

    @pytest.mark.parametrize("s", [160, 96, 37])
    def test_non_divisible_seq_runs(self, rng, s):
        b, hq, hkv, d = 1, 4, 2, 32
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (b, s, hq, d))
        k = jax.random.normal(ks[1], (b, s, hkv, d))
        v = jax.random.normal(ks[2], (b, s, hkv, d))
        out = ops.flash_attention(q, k, v, causal=True, interpret=True)
        expect = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("kind,window", [("causal", 4096),
                                             ("local", 64), ("bidir", 4096)])
    def test_attention_dispatch_pallas(self, rng, kind, window):
        from repro.models.attention import attention
        b, s, hq, hkv, d = 2, 128, 4, 2, 32
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (b, s, hq, d))
        k = jax.random.normal(ks[1], (b, s, hkv, d))
        v = jax.random.normal(ks[2], (b, s, hkv, d))
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        out = attention(q, k, v, pos_q=pos, pos_k=pos, kind=kind,
                        window=window, impl="pallas", chunk=64)
        expect = attention(q, k, v, pos_q=pos, pos_k=pos, kind=kind,
                           window=window, impl="naive")
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-5, atol=2e-5)

    def test_pallas_dispatch_guards_nonstandard_positions(self, rng):
        """Concrete positions that aren't the arange layout (offsets,
        PAD_POS sentinels) must fall back to the jnp paths — the flash
        kernel's offset-derived masks would be silently wrong."""
        from repro.models.attention import attention
        b, s, hq, hkv, d = 1, 64, 4, 2, 32
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (b, s, hq, d))
        k = jax.random.normal(ks[1], (b, s, hkv, d))
        v = jax.random.normal(ks[2], (b, s, hkv, d))
        pos = jnp.broadcast_to(jnp.arange(s) + 7, (b, s))   # offset layout
        out = attention(q, k, v, pos_q=pos, pos_k=pos, impl="pallas")
        expect = attention(q, k, v, pos_q=pos, pos_k=pos, impl="naive")
        np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


class TestDecodeLengthBound:
    def test_sliced_equals_full(self, rng):
        """decode_attention(length=...) must be bit-identical: entries at
        >= length are provably masked, and masked entries contribute
        exact zeros to the softmax."""
        from repro.models.attention import decode_attention
        b, smax, hq, hkv, d = 3, 64, 4, 2, 16
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (b, 1, hq, d))
        kc = jax.random.normal(ks[1], (b, smax, hkv, d))
        vc = jax.random.normal(ks[2], (b, smax, hkv, d))
        pos = jnp.asarray([3, 17, 23])
        full = decode_attention(q, kc, vc, pos=pos)
        sliced = decode_attention(q, kc, vc, pos=pos, length=24)
        np.testing.assert_array_equal(np.asarray(full), np.asarray(sliced))
        loc = decode_attention(q, kc, vc, pos=pos, kind="local", window=8)
        loc_b = decode_attention(q, kc, vc, pos=pos, kind="local", window=8,
                                 length=24)
        np.testing.assert_array_equal(np.asarray(loc), np.asarray(loc_b))


class TestSSDScan:
    @pytest.mark.parametrize("shape", [
        (1, 64, 2, 16, 1, 8), (2, 128, 4, 32, 2, 16), (1, 256, 8, 64, 1, 32),
    ])
    def test_shape_sweep(self, rng, shape):
        b, s, h, p, g, n = shape
        ks = jax.random.split(rng, 5)
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)))
        bb = jax.random.normal(ks[3], (b, s, g, n))
        cc = jax.random.normal(ks[4], (b, s, g, n))
        y = ops.ssd_scan(x, dt, a, bb, cc, chunk=32, interpret=True)
        expect = ref.ssd_scan_ref(x, dt, a, bb, cc)
        np.testing.assert_allclose(np.asarray(y), np.asarray(expect),
                                   rtol=2e-4, atol=2e-4)

    def test_bf16(self, rng):
        b, s, h, p, g, n = 1, 64, 2, 16, 1, 8
        ks = jax.random.split(rng, 5)
        x = jax.random.normal(ks[0], (b, s, h, p), jnp.bfloat16)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))
                             ).astype(jnp.bfloat16)
        a = -jnp.exp(jax.random.normal(ks[2], (h,)))
        bb = jax.random.normal(ks[3], (b, s, g, n), jnp.bfloat16)
        cc = jax.random.normal(ks[4], (b, s, g, n), jnp.bfloat16)
        y = ops.ssd_scan(x, dt, a, bb, cc, chunk=32, interpret=True)
        expect = ref.ssd_scan_ref(x, dt, a, bb, cc)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(expect, np.float32),
                                   rtol=1e-1, atol=1e-1)


class TestFusedLogprob:
    @pytest.mark.parametrize("shape", [(64, 512), (128, 1024), (32, 256)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, rng, shape, dtype):
        t, v = shape
        ks = jax.random.split(rng, 2)
        logits = (5 * jax.random.normal(ks[0], (t, v))).astype(dtype)
        tgt = jax.random.randint(ks[1], (t,), 0, v)
        lp, ent = ops.fused_logprob(logits, tgt, block_t=16, block_v=128,
                                    interpret=True)
        lp_e, ent_e = ref.fused_logprob_ref(logits, tgt)
        tol = dict(rtol=1e-2, atol=1e-2) if dtype == jnp.bfloat16 \
            else dict(rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lp_e), **tol)
        np.testing.assert_allclose(np.asarray(ent), np.asarray(ent_e),
                                   **tol)

    def test_logprobs_are_valid(self, rng):
        logits = 3 * jax.random.normal(rng, (32, 512))
        tgt = jnp.zeros((32,), jnp.int32)
        lp, ent = ops.fused_logprob(logits, tgt, block_t=16, block_v=128,
                                    interpret=True)
        assert bool((lp <= 0).all())
        assert bool((ent >= 0).all())
