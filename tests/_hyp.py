"""Optional-``hypothesis`` shim for the property-based tests.

``pip install -e .[dev]`` (and CI) provide the real library, and the
property tests then run at full strength. On a bare checkout without
``hypothesis`` the suite must still *collect* and run the non-property
tests, so this module exports stand-ins that mark each property test as
skipped instead of exploding at import time.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                     # pragma: no cover
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert placeholder accepted anywhere a strategy is expected."""

        def map(self, fn):
            return self

        def __call__(self, *a, **k):
            return self

    class _Strategies:
        def __getattr__(self, name):
            if name == "composite":
                # @st.composite functions become callables returning a
                # placeholder strategy.
                return lambda fn: _Strategy()
            return lambda *a, **k: _Strategy()

    st = _Strategies()

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -e .[dev])"
            )(fn)
        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
