"""End-to-end behaviour tests for the full HeteroRL/GEPO system: SFT warm
start → online RL → hetero RL on the synthetic verifiable-math task, with
the paper's stability diagnostics coming out of the loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (HeteroConfig, ModelConfig, RLConfig, TrainConfig,
                          ATTN, MLP)
from repro.data import ArithmeticTask, Tokenizer
from repro.hetero import HeteroRuntime, run_online
from repro.launch.train import make_eval_fn, sft_warmstart
from repro.models import init_params
from repro.training import init_state

TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                   num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=32,
                   block_pattern=(ATTN,), ffn_pattern=(MLP,),
                   dtype="float32", attn_impl="naive", remat=False,
                   rope_theta=1e4)


@pytest.fixture(scope="module")
def warm_state():
    """One SFT warm start shared by the e2e tests (the paper RL-tunes a
    pretrained model)."""
    task = ArithmeticTask(max_operand=9, ops="+", prompt_width=5, seed=0)
    tok = Tokenizer()
    tc = TrainConfig(learning_rate=1e-2, total_steps=250)
    state = init_state(TINY, tc, init_params(TINY, jax.random.PRNGKey(0)))
    state, loss = sft_warmstart(TINY, tc, task, tok, state, steps=250,
                                batch=64)
    assert loss < 2.0
    return state, task, tok


def test_online_rl_runs_and_logs_diagnostics(warm_state):
    state, task, tok = warm_state
    rl = RLConfig(loss_type="gepo", group_size=4, beta_kl=0.0,
                  max_new_tokens=5, temperature=1.0, top_k=0, top_p=1.0)
    tc = TrainConfig(learning_rate=1e-3, total_steps=12)
    hist, evals, learner = run_online(
        TINY, rl, tc, task, tok, state._replace(step=jnp.zeros((),
                                                               jnp.int32)),
        num_steps=12, prompts_per_batch=4,
        eval_fn=make_eval_fn(TINY, rl, task, tok, n_prompts=8),
        eval_every=6)
    assert learner.step == 12
    for key in ("iw_var", "kl", "est_error", "reward_mean", "grad_norm"):
        vals = hist.get(key)
        assert len(vals) == 12 and np.isfinite(vals).all(), key
    assert len(evals) == 2
    # online: sampler == learner, so KL ≈ 0 and IW ≈ 1
    assert hist.get("kl").max() < 0.3
    assert abs(hist.get("iw_mean") - 1.0).max() < 0.5


def test_hetero_rl_staleness_and_stability_metrics(warm_state):
    state, task, tok = warm_state
    rl = RLConfig(loss_type="gepo", group_size=4, beta_kl=0.005,
                  max_new_tokens=5, temperature=1.0, top_k=0, top_p=1.0)
    tc = TrainConfig(learning_rate=1e-3, total_steps=10)
    hcfg = HeteroConfig(num_samplers=2, max_delay_steps=64,
                        delay_median_s=600.0, seed=1)
    rt = HeteroRuntime(TINY, rl, tc, hcfg, task, tok,
                       state._replace(step=jnp.zeros((), jnp.int32)),
                       prompts_per_batch=4)
    hist = rt.run(10)
    assert rt.learner.step == 10
    stale = hist.get("staleness")
    assert stale.max() > 0, "delayed syncs must induce staleness"
    assert stale.max() <= 64
    assert np.isfinite(hist.get("iw_var")).all()


def test_gepo_weights_stay_bounded_under_staleness(warm_state):
    """GEPO's group-expectation weights remain well-conditioned even with
    a deliberately divergent sampler (the paper's variance claim, e2e)."""
    state, task, tok = warm_state
    rl_gepo = RLConfig(loss_type="gepo", group_size=4, beta_kl=0.005,
                       max_new_tokens=5, temperature=1.0, top_k=0,
                       top_p=1.0)
    tc = TrainConfig(learning_rate=2e-3, total_steps=16)
    hcfg = HeteroConfig(num_samplers=2, max_delay_steps=64,
                        delay_median_s=1500.0, seed=2,
                        delay_distribution="weibull")
    rt = HeteroRuntime(TINY, rl_gepo, tc, hcfg, task, tok,
                       state._replace(step=jnp.zeros((), jnp.int32)),
                       prompts_per_batch=4)
    hist = rt.run(16)
    assert float(hist.get("iw_max").max()) < 50.0
