import jax
import pytest

# Smoke tests and benches must see ONE device — the 512-device fake mesh
# is set only inside repro/launch/dryrun.py (and the subprocess test).
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
