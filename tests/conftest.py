import jax
import pytest

# Smoke tests and benches must see ONE device — the 512-device fake mesh
# is set only inside repro/launch/dryrun.py (and the subprocess test).
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


# The full suite accumulates hundreds of jitted executables; XLA's CPU
# backend can segfault compiling late modules under that accumulated
# state (reproducible at tests/test_sampling_data.py when the 13 prior
# modules run first). Dropping executable caches between modules keeps
# each module's compilation independent — same idiom as the
# jax.clear_caches() between benchmark modules in benchmarks/run.py.
@pytest.fixture(scope="module", autouse=True)
def _bounded_executable_cache():
    yield
    jax.clear_caches()
