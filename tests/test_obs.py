"""Tests for the unified observability spine (repro.obs): registry
semantics, tracer span/flow behavior, Chrome-trace export validity, the
bounded-reservoir SLO percentiles, and the recompile-sentinel mirror."""
from __future__ import annotations

import json
import math
import threading

import numpy as np
import pytest

from repro.obs import (MetricsRegistry, Reservoir, Tracer, chrome_trace,
                       validate_chrome_trace, write_chrome_trace,
                       write_jsonl)
from repro.obs.registry import MAX_CHILDREN_PER_FAMILY
from repro.serving.api import GenerationResult
from repro.serving.telemetry import ServeTelemetry, percentile


# ---------------------------------------------------------------------------
class TestPercentile:
    def test_empty_is_nan(self):
        assert math.isnan(percentile([], 50))

    def test_all_nan_is_nan(self):
        assert math.isnan(percentile([float("nan")] * 3, 99))

    def test_single_element_any_q(self):
        for q in (0, 1, 50, 99, 100):
            assert percentile([7.0], q) == 7.0

    def test_q0_is_min_q100_is_max(self):
        vals = [5.0, 1.0, 3.0, 2.0, 4.0]
        assert percentile(vals, 0) == 1.0
        assert percentile(vals, 100) == 5.0

    def test_nearest_rank_median(self):
        assert percentile([1.0, 2.0, 3.0, 4.0], 50) == 2.0
        assert percentile([1.0, 2.0, 3.0], 50) == 2.0

    def test_nan_values_filtered(self):
        assert percentile([float("nan"), 2.0, 1.0], 100) == 2.0

    def test_out_of_range_q_raises(self):
        with pytest.raises(ValueError):
            percentile([1.0], -0.1)
        with pytest.raises(ValueError):
            percentile([1.0], 100.1)

    def test_accepts_any_iterable(self):
        assert percentile(iter((3.0, 1.0)), 100) == 3.0


# ---------------------------------------------------------------------------
class TestRegistry:
    def test_counter_gauge_histogram(self):
        reg = MetricsRegistry(enabled=True)
        c = reg.counter("reqs_total", "requests")
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5
        g = reg.gauge("depth")
        g.set(4)
        g.add(-1)
        assert g.value == 3.0
        h = reg.histogram("lat_seconds", buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 5.0):
            h.observe(v)
        assert h.counts == [1, 1, 1] and h.count == 3
        assert h.sum == pytest.approx(5.55)

    def test_counter_negative_inc_raises(self):
        reg = MetricsRegistry(enabled=True)
        with pytest.raises(ValueError):
            reg.counter("c").inc(-1)

    def test_gauge_add_from_unset_starts_at_value(self):
        reg = MetricsRegistry(enabled=True)
        g = reg.gauge("g")
        g.add(2.0)                 # NaN start must not propagate
        assert g.value == 2.0

    def test_histogram_skips_nan(self):
        reg = MetricsRegistry(enabled=True)
        h = reg.histogram("h")
        h.observe(float("nan"))
        assert h.count == 0

    def test_handles_are_idempotent_and_label_scoped(self):
        reg = MetricsRegistry(enabled=True)
        a = reg.counter("syncs_total", sampler=0)
        b = reg.counter("syncs_total", sampler=0)
        other = reg.counter("syncs_total", sampler=1)
        assert a is b and a is not other
        a.inc()
        assert other.value == 0.0

    def test_kind_conflict_raises(self):
        reg = MetricsRegistry(enabled=True)
        reg.counter("x")
        with pytest.raises(ValueError):
            reg.gauge("x")

    def test_label_cardinality_capped(self):
        reg = MetricsRegistry(enabled=True)
        for i in range(MAX_CHILDREN_PER_FAMILY):
            reg.counter("burst_total", rid=i)
        with pytest.raises(ValueError):
            reg.counter("burst_total", rid=MAX_CHILDREN_PER_FAMILY)

    def test_disabled_mutators_are_noops(self):
        reg = MetricsRegistry(enabled=False)
        c, g, h = reg.counter("c"), reg.gauge("g"), reg.histogram("h")
        c.inc(5)
        g.set(1)
        h.observe(0.2)
        reg.set_many("pfx", {"a": 1.0})
        assert c.value == 0.0 and math.isnan(g.value) and h.count == 0

    def test_late_enable_flips_bound_handles(self):
        reg = MetricsRegistry(enabled=False)
        c = reg.counter("c")         # bound while disabled
        c.inc()
        reg.enabled = True
        c.inc()
        assert c.value == 1.0

    def test_clear_resets_values_but_keeps_bound_handles(self):
        reg = MetricsRegistry(enabled=True)
        c = reg.counter("c")
        h = reg.histogram("h", buckets=(1.0,))
        c.inc(3)
        h.observe(0.5)
        reg.clear()
        assert c.value == 0.0 and h.count == 0
        c.inc()                   # the pre-clear handle still records...
        assert reg.snapshot()["c"] == 1.0   # ...and exporters still see it

    def test_set_many_fans_into_gauges(self):
        reg = MetricsRegistry(enabled=True)
        reg.set_many("learner", {"kl": 0.1, "skipme": "not-a-number"},
                     sampler=2)
        snap = reg.snapshot()
        assert snap['learner_kl{sampler="2"}'] == pytest.approx(0.1)
        assert not any("skipme" in k for k in snap)

    def test_prometheus_text_format(self):
        reg = MetricsRegistry(enabled=True)
        reg.counter("reqs_total", "requests served").inc(2)
        h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
        h.observe(0.05)
        h.observe(0.5)
        h.observe(5.0)
        text = reg.prometheus_text()
        assert "# HELP reqs_total requests served" in text
        assert "# TYPE reqs_total counter" in text
        assert "reqs_total 2" in text
        assert "# TYPE lat_seconds histogram" in text
        assert 'lat_seconds_bucket{le="0.1"} 1' in text     # cumulative
        assert 'lat_seconds_bucket{le="1"} 2' in text
        assert 'lat_seconds_bucket{le="+Inf"} 3' in text
        assert "lat_seconds_count 3" in text

    def test_name_sanitized(self):
        reg = MetricsRegistry(enabled=True)
        c = reg.counter("bad-name.with spaces")
        assert c.name == "bad_name_with_spaces"

    def test_concurrent_incs_are_exact(self):
        reg = MetricsRegistry(enabled=True)
        c = reg.counter("c")

        def worker():
            for _ in range(1000):
                c.inc()
        ts = [threading.Thread(target=worker) for _ in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert c.value == 4000.0


# ---------------------------------------------------------------------------
class TestReservoir:
    def test_exact_below_capacity(self):
        r = Reservoir(capacity=10)
        for v in range(5):
            r.append(v)
        assert r.values == [0.0, 1.0, 2.0, 3.0, 4.0] and r.n == 5

    def test_bounded_beyond_capacity(self):
        r = Reservoir(capacity=16, seed=3)
        for v in range(10_000):
            r.add(v)
        assert len(r) == 16 and r.n == 10_000
        assert all(0 <= v < 10_000 for v in r)

    def test_seed_determinism(self):
        a, b = Reservoir(8, seed=7), Reservoir(8, seed=7)
        for v in range(1000):
            a.add(v)
            b.add(v)
        assert a.values == b.values

    def test_capacity_validation(self):
        with pytest.raises(ValueError):
            Reservoir(capacity=0)


# ---------------------------------------------------------------------------
class _FakeSim:
    def __init__(self):
        self.now = 0.0


class TestTracer:
    def test_disabled_span_is_shared_noop(self):
        tr = Tracer(enabled=False)
        s1, s2 = tr.span("a"), tr.span("b", slot=1)
        assert s1 is s2                     # no allocation when disabled
        with s1:
            pass
        assert len(tr) == 0
        tr.instant("i")
        tr.complete("c", 0.0, 1.0)
        tr.async_begin("f", 1)
        assert len(tr) == 0

    def test_span_records_duration_and_args(self):
        tr = Tracer(enabled=True)
        with tr.span("prefill", track="engine", slot=3):
            pass
        (ev,) = tr.events()
        assert ev["ph"] == "X" and ev["name"] == "prefill"
        assert ev["dur"] >= 0.0 and ev["track"] == "engine"
        assert ev["args"]["slot"] == 3

    def test_span_nesting_orders_child_first(self):
        tr = Tracer(enabled=True)
        with tr.track("learner"):
            with tr.span("outer"):
                with tr.span("inner"):
                    pass
        inner, outer = tr.events()
        assert inner["name"] == "inner" and outer["name"] == "outer"
        assert inner["track"] == outer["track"] == "learner"
        assert outer["dur"] >= inner["dur"]
        assert outer["ts"] <= inner["ts"]

    def test_span_exception_safe_and_tagged(self):
        tr = Tracer(enabled=True)
        with pytest.raises(RuntimeError):
            with tr.span("step"):
                raise RuntimeError("boom")
        (ev,) = tr.events()
        assert ev["args"]["error"] == "RuntimeError"
        assert ev["dur"] >= 0.0             # still closed with a duration

    def test_track_stack_pops_on_exit(self):
        tr = Tracer(enabled=True)
        with tr.track("a"):
            with tr.track("b"):
                assert tr.current_track() == "b"
            assert tr.current_track() == "a"

    def test_sim_clock_drives_timestamps(self):
        tr = Tracer(enabled=True)
        sim = _FakeSim()
        tr.use_sim(sim)
        sim.now = 5.0
        with tr.span("gen"):
            sim.now = 7.5
        (ev,) = tr.events()
        assert ev["ts"] == 5.0 and ev["dur"] == 2.5
        tr.use_wall_clock()
        assert tr.now() != 5.0 or tr.now() >= 0.0

    def test_complete_emits_explicit_window(self):
        tr = Tracer(enabled=True)
        tr.complete("step_window", 10.0, 38.125, track="learner", step=3)
        (ev,) = tr.events()
        assert ev["ts"] == 10.0 and ev["dur"] == pytest.approx(28.125)

    def test_async_flow_ids_are_unique(self):
        tr = Tracer(enabled=True)
        ids = {tr.next_flow_id() for _ in range(100)}
        assert len(ids) == 100
        fid = tr.next_flow_id()
        tr.async_begin("chunk", fid, cat="transport", ts=1.0, bytes=64)
        tr.async_end("chunk", fid, cat="transport", ts=2.0)
        b, e = tr.events()
        assert b["ph"] == "b" and e["ph"] == "e" and b["id"] == e["id"]

    def test_ring_buffer_bounds_memory(self):
        tr = Tracer(enabled=True, max_events=8)
        for i in range(100):
            tr.instant(f"i{i}")
        assert len(tr) == 8
        assert tr.events()[0]["name"] == "i92"   # oldest fell off


# ---------------------------------------------------------------------------
class TestExport:
    def _traced(self, sim=False):
        tr = Tracer(enabled=True)
        if sim:
            s = _FakeSim()
            tr.use_sim(s)
            s.now = 1.0
        with tr.track("learner"):
            with tr.span("learner_step", step=1):
                pass
        with tr.track("sampler-0"):
            with tr.span("sampler_generate"):
                pass
        fid = tr.next_flow_id()
        tr.async_begin("chunk_transfer", fid, ts=0.1)
        tr.async_end("chunk_transfer", fid, ts=0.2)
        return tr

    def test_chrome_trace_tracks_map_to_tids(self):
        obj = chrome_trace(self._traced())
        names = {e["args"]["name"] for e in obj["traceEvents"]
                 if e["ph"] == "M" and e["name"] == "thread_name"}
        assert {"learner", "sampler-0"} <= names
        tids = {e["tid"] for e in obj["traceEvents"] if e["ph"] != "M"}
        assert len(tids) >= 2

    def test_write_and_validate_roundtrip(self, tmp_path):
        p = str(tmp_path / "trace.json")
        n = write_chrome_trace(self._traced(), p)
        assert validate_chrome_trace(p) == n == 4

    def test_sim_clock_trace_validates_identically(self, tmp_path):
        p = str(tmp_path / "sim_trace.json")
        write_chrome_trace(self._traced(sim=True), p)
        assert validate_chrome_trace(p) == 4
        with open(p) as f:
            obj = json.load(f)
        xs = [e for e in obj["traceEvents"] if e["ph"] == "X"]
        assert all(e["ts"] == pytest.approx(1e6) for e in xs)  # sim µs

    def test_validate_rejects_malformed(self, tmp_path):
        p = str(tmp_path / "bad.json")
        with open(p, "w") as f:
            json.dump({"traceEvents": [{"ph": "X", "ts": 0.0}]}, f)
        with pytest.raises(ValueError):
            validate_chrome_trace(p)        # missing name
        with open(p, "w") as f:
            json.dump({"traceEvents": [
                {"name": "a", "ph": "X", "ts": 0.0}]}, f)
        with pytest.raises(ValueError):
            validate_chrome_trace(p)        # duration event missing dur
        with open(p, "w") as f:
            json.dump({"traceEvents": [
                {"name": "a", "ph": "b", "ts": 0.0}]}, f)
        with pytest.raises(ValueError):
            validate_chrome_trace(p)        # async event missing id

    def test_jsonl_export(self, tmp_path):
        p = str(tmp_path / "events.jsonl")
        n = write_jsonl(self._traced(), p)
        with open(p) as f:
            lines = [json.loads(ln) for ln in f]
        assert len(lines) == n == 4
        assert lines[0]["name"] == "learner_step"


# ---------------------------------------------------------------------------
class TestSentinelMirror:
    def test_compile_events_count_into_registry(self):
        from repro import obs
        from repro.analysis import sentinel
        was = obs.metrics.enabled
        obs.metrics.enabled = True
        try:
            before = sentinel._M_COMPILES.value
            sentinel._on_event(sentinel._COMPILE_EVENT, 0.25)
            sentinel._on_event("/jax/unrelated/event", 0.25)
            assert sentinel._M_COMPILES.value == before + 1
            assert sentinel._M_COMPILE_SECONDS.value >= 0.25
        finally:
            obs.metrics.enabled = was

    def test_install_metrics_listener_idempotent(self):
        from repro.analysis.sentinel import install_metrics_listener
        install_metrics_listener()
        install_metrics_listener()          # must not double-register


# ---------------------------------------------------------------------------
def _result(i: int, ttft: float, lat: float) -> GenerationResult:
    return GenerationResult(rid=i, tokens=np.zeros(3, np.int32),
                            logps=np.zeros(3, np.float32),
                            finish_reason="eos", prompt_len=4,
                            prefix_hit_tokens=2, ttft_s=ttft, latency_s=lat)


class TestServeTelemetryBounded:
    def test_reservoirs_bound_memory(self):
        reg = MetricsRegistry(enabled=True)
        tel = ServeTelemetry(2, registry=reg, reservoir_capacity=32)
        for i in range(1000):
            tel.record(_result(i, ttft=0.01 * i, lat=0.02 * i), done_s=i)
        assert len(tel.ttfts) == 32 and len(tel.latencies) == 32
        assert tel.completed == 1000
        snap = tel.snapshot()
        assert 0.0 <= snap["ttft_p50_s"] <= 0.01 * 999
        assert snap["tokens_out"] == 3000

    def test_registry_mirror(self):
        reg = MetricsRegistry(enabled=True)
        tel = ServeTelemetry(2, registry=reg)
        tel.record(_result(0, 0.01, 0.05), done_s=0.0)
        tel.record(GenerationResult(rid=1, tokens=np.zeros(0, np.int32),
                                    logps=np.zeros(0, np.float32),
                                    finish_reason="expired", prompt_len=4))
        snap = reg.snapshot()
        assert snap["serve_requests_completed_total"] == 1
        assert snap["serve_requests_expired_total"] == 1
        assert snap["serve_ttft_seconds_count"] == 1
        assert "serve_ttft_seconds" in reg.prometheus_text()

    def test_deterministic_percentiles_same_seed(self):
        reg = MetricsRegistry(enabled=False)
        a = ServeTelemetry(1, registry=reg, reservoir_capacity=16, seed=5)
        b = ServeTelemetry(1, registry=reg, reservoir_capacity=16, seed=5)
        for i in range(500):
            a.record(_result(i, 0.001 * i, 0.002 * i))
            b.record(_result(i, 0.001 * i, 0.002 * i))
        assert a.snapshot()["ttft_p99_s"] == b.snapshot()["ttft_p99_s"]

    def test_default_capacity_matches_contract(self):
        reg = MetricsRegistry(enabled=False)
        tel = ServeTelemetry(1, registry=reg)
        assert tel.reservoir_capacity == 4096


# ---------------------------------------------------------------------------
class TestConfigure:
    def test_module_configure_flips_and_restores(self):
        from repro import obs
        assert not obs.metrics.enabled and not obs.trace.enabled
        try:
            obs.configure(True, clear=True)
            assert obs.enabled()
            with obs.trace.span("x"):
                pass
            assert len(obs.trace) == 1
            sim = _FakeSim()
            obs.configure(True, sim=sim, clear=True)
            sim.now = 3.0
            assert obs.trace.now() == 3.0
        finally:
            obs.configure(False, clear=True)
        assert not obs.enabled()
        assert obs.trace.now() != 3.0       # wall clock restored
