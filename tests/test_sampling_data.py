"""Sampling (top-k/top-p/temperature) properties, generation-engine EOS
semantics, data pipeline and localized rewards."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st   # hypothesis, or skip-shim without it

from repro.config import ModelConfig, RLConfig, ATTN, MLP
from repro.data import (ArithmeticTask, PromptPipeline, Tokenizer,
                        encode_prompts, score_rollouts)
from repro.data.tasks import EOS, PAD
from repro.models import init_params
from repro.sampling import filter_logits, generate, sample_token, token_logps

TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                   num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=32,
                   block_pattern=(ATTN,), ffn_pattern=(MLP,),
                   dtype="float32", attn_impl="naive", remat=False,
                   rope_theta=1e4)


class TestFiltering:
    @given(st.integers(1, 16), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=50, deadline=None)
    def test_top_k_keeps_exactly_k(self, k, seed):
        logits = jax.random.normal(jax.random.PRNGKey(seed), (1, 16))
        out = filter_logits(logits, top_k=k)
        kept = int((np.asarray(out) > -1e29).sum())
        assert kept == min(k, 16)

    @given(st.floats(0.1, 0.99), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=50, deadline=None)
    def test_top_p_mass_at_least_p(self, p, seed):
        logits = jax.random.normal(jax.random.PRNGKey(seed), (1, 32))
        out = np.asarray(filter_logits(logits, top_p=p))
        probs = np.exp(logits[0]) / np.exp(logits[0]).sum()
        kept_mass = probs[out[0] > -1e29].sum()
        assert kept_mass >= p - 1e-4

    def test_top_p_1_keeps_all(self, rng):
        logits = jax.random.normal(rng, (2, 16))
        np.testing.assert_array_equal(np.asarray(filter_logits(
            logits, top_p=1.0)), np.asarray(logits))

    def test_argmax_invariant_under_temperature(self, rng):
        logits = jax.random.normal(rng, (4, 32))
        for t in (0.1, 0.5, 2.0):
            f = filter_logits(logits, temperature=t)
            np.testing.assert_array_equal(np.asarray(f.argmax(-1)),
                                          np.asarray(logits.argmax(-1)))

    def test_sample_token_returns_model_logp(self, rng):
        logits = jax.random.normal(rng, (4, 32))
        tok, lp_filt, lp_model = sample_token(rng, logits, temperature=0.6,
                                              top_k=5)
        expect = jax.nn.log_softmax(logits)[jnp.arange(4), tok]
        np.testing.assert_allclose(np.asarray(lp_model), np.asarray(expect),
                                   rtol=1e-5)


class TestEngine:
    def test_generation_stops_at_eos_and_masks(self, rng):
        params = init_params(TINY, rng)
        prompts = jax.random.randint(rng, (4, 5), 3, TINY.vocab_size)
        rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0,
                      max_new_tokens=12)
        roll = generate(TINY, rl, params, prompts, rng, vocab_limit=20)
        comp = np.asarray(roll["completions"])
        mask = np.asarray(roll["comp_mask"])
        for row, mrow in zip(comp, mask):
            if EOS in row.tolist():
                t = row.tolist().index(EOS)
                assert mrow[t] == 1.0            # EOS itself counted
                assert (row[t + 1:] == PAD).all()
                assert (mrow[t + 1:] == 0).all()

    def test_sampler_lp_matches_recompute(self, rng):
        """Engine-side logps equal the teacher-forced recompute (no
        vLLM/FSDP-style mismatch in our engine — the recompute knob is
        faithfulness, not necessity)."""
        params = init_params(TINY, rng)
        prompts = jax.random.randint(rng, (4, 5), 3, TINY.vocab_size)
        rl = RLConfig(temperature=1.0, top_k=0, top_p=1.0,
                      max_new_tokens=8)
        roll = generate(TINY, rl, params, prompts, rng,
                        vocab_limit=TINY.vocab_size)
        lp = token_logps(TINY, params, roll["tokens"])
        comp_lp = np.asarray(lp[:, prompts.shape[1] - 1:])
        m = np.asarray(roll["comp_mask"])
        np.testing.assert_allclose(comp_lp * m,
                                   np.asarray(roll["sampler_lp"]) * m,
                                   rtol=1e-4, atol=1e-4)


class TestData:
    def test_tokenizer_roundtrip(self):
        tok = Tokenizer()
        s = "12+34= 56"
        assert tok.decode(tok.encode(s)) == s

    def test_reward_exact_match(self):
        task = ArithmeticTask(seed=0)
        p = task.sample()
        assert task.reward(p, p.answer) == 1.0
        assert task.reward(p, p.answer + "9") == 0.0
        assert task.reward(p, " " + p.answer + " ") == 1.0

    def test_prompt_width_fixed(self):
        task = ArithmeticTask(max_operand=99, prompt_width=8, seed=1)
        tok = Tokenizer()
        enc = encode_prompts(tok, task.sample_batch(32))
        assert enc.shape == (32, 8)

    def test_group_replication(self):
        task = ArithmeticTask(seed=2)
        pipe = PromptPipeline(task, Tokenizer(), prompts_per_batch=4,
                              group_size=8)
        req = pipe.next_batch()
        assert req.prompts.shape[0] == 32
        for g in range(4):
            rows = req.prompts[g * 8:(g + 1) * 8]
            assert (rows == rows[0]).all()       # one prompt per group

    def test_localized_rewards_groupwise(self):
        """App. F: rewards computed per group with no cross-group info."""
        task = ArithmeticTask(seed=3)
        tok = Tokenizer()
        probs = task.sample_batch(2)
        comp = np.zeros((8, 4), np.int64)
        right = tok.encode(probs[0].answer)
        comp[1, :len(right)] = right
        comp[1, len(right):] = EOS
        r = score_rollouts(task, tok, probs, comp, group_size=4)
        assert r[1] == 1.0 and r.sum() == 1.0
