"""Batched serving with the KV-cache engine — what a HeteroRL sampler node
runs. Uses a reduced Qwen2-family config; full-size serving paths are
exercised shape-exactly by the dry-run.

    PYTHONPATH=src python examples/serve_batch.py --arch qwen2-7b
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0]] + (sys.argv[1:] or
                                ["--arch", "qwen2-7b", "--batch", "8",
                                 "--max-new", "12", "--rounds", "2"])
    main()
