"""Quickstart: GEPO online RL on the verifiable-arithmetic task in ~2 min.

    PYTHONPATH=src python examples/quickstart.py

Trains a tiny LM (SFT warm start → GEPO), printing the paper's stability
diagnostics (IW variance, KL, reward) as it goes.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, RLConfig, TrainConfig, ATTN, MLP
from repro.data import ArithmeticTask, Tokenizer
from repro.hetero import run_online
from repro.launch.train import make_eval_fn, sft_warmstart
from repro.models import init_params
from repro.training import init_state

cfg = ModelConfig(name="quickstart-lm", family="dense", num_layers=2,
                  d_model=96, num_heads=4, num_kv_heads=2, d_ff=192,
                  vocab_size=32, block_pattern=(ATTN,), ffn_pattern=(MLP,),
                  dtype="float32", attn_impl="naive", remat=False,
                  rope_theta=1e4)
rl = RLConfig(loss_type="gepo", group_size=8, beta_kl=0.0,
              max_new_tokens=6, temperature=1.0, top_k=0, top_p=1.0)
task = ArithmeticTask(max_operand=20, ops="+", prompt_width=6, seed=0)
tok = Tokenizer()

print("== SFT warm start (the paper RL-tunes a pretrained model) ==")
tc_sft = TrainConfig(learning_rate=1e-2, total_steps=300)
state = init_state(cfg, tc_sft, init_params(cfg, jax.random.PRNGKey(0)))
state, loss = sft_warmstart(cfg, tc_sft, task, tok, state, steps=300)
print(f"SFT loss: {loss:.3f}")

print("== GEPO online RL ==")
tc = TrainConfig(learning_rate=1e-3, total_steps=40)
state = state._replace(step=jnp.zeros((), jnp.int32))
hist, evals, learner = run_online(
    cfg, rl, tc, task, tok, state, num_steps=40, prompts_per_batch=8,
    eval_fn=make_eval_fn(cfg, rl, task, tok), eval_every=10)

for i in range(0, 40, 10):
    print(f"step {i:3d}: reward={hist.get('reward_mean')[i]:.3f} "
          f"iw_var={hist.get('iw_var')[i]:.2e} "
          f"kl={hist.get('kl')[i]:.2e}")
print(f"eval scores: {['%.3f' % e for e in evals]}")
print(f"final reward (last 10 steps): "
      f"{np.mean(hist.get('reward_mean')[-10:]):.3f}")
