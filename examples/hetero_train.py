"""HeteroRL end-to-end: 1 learner + 4 samplers over simulated WAN latency
(log-normal, bounded 60–1800 s), staleness window 64 learner steps —
the paper's Fig. 3 topology, compressed to CPU scale.

    PYTHONPATH=src python examples/hetero_train.py [--method gspo]

Compare `--method gepo` (stable) vs `--method gspo` (the paper's unstable
baseline) via the printed IW-variance / staleness traces.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import (HeteroConfig, ModelConfig, RLConfig, TrainConfig,
                          ATTN, MLP)
from repro.data import ArithmeticTask, Tokenizer
from repro.hetero import HeteroRuntime
from repro.launch.train import make_eval_fn, sft_warmstart
from repro.models import init_params
from repro.training import init_state

ap = argparse.ArgumentParser()
ap.add_argument("--method", default="gepo")
ap.add_argument("--steps", type=int, default=40)
ap.add_argument("--delay-median", type=float, default=900.0)
ap.add_argument("--dist", default="lognormal")
args = ap.parse_args()

cfg = ModelConfig(name="hetero-lm", family="dense", num_layers=2,
                  d_model=96, num_heads=4, num_kv_heads=2, d_ff=192,
                  vocab_size=32, block_pattern=(ATTN,), ffn_pattern=(MLP,),
                  dtype="float32", attn_impl="naive", remat=False,
                  rope_theta=1e4)
rl = RLConfig(loss_type=args.method, group_size=8, beta_kl=0.005,
              max_new_tokens=6, temperature=1.0, top_k=0, top_p=1.0)
task = ArithmeticTask(max_operand=20, ops="+", prompt_width=6, seed=0)
tok = Tokenizer()

tc_sft = TrainConfig(learning_rate=1e-2, total_steps=300)
state = init_state(cfg, tc_sft, init_params(cfg, jax.random.PRNGKey(0)))
state, _ = sft_warmstart(cfg, tc_sft, task, tok, state, steps=300)
state = state._replace(step=jnp.zeros((), jnp.int32))

hcfg = HeteroConfig(num_samplers=4, max_delay_steps=64,
                    delay_distribution=args.dist,
                    delay_median_s=args.delay_median, seed=0)
tc = TrainConfig(learning_rate=1e-3, total_steps=args.steps)
rt = HeteroRuntime(cfg, rl, tc, hcfg, task, tok, state,
                   prompts_per_batch=8,
                   eval_fn=make_eval_fn(cfg, rl, task, tok), eval_every=10)
hist = rt.run(args.steps)

print(f"\n== {args.method} under {args.dist} delay "
      f"(median {args.delay_median:.0f}s, window 64 steps) ==")
print(f"learner steps: {rt.learner.step}, sim time {rt.sim.now:.0f}s, "
      f"discarded stale batches: {rt.learner.discarded}")
print(f"staleness: mean={hist.get('staleness').mean():.1f} "
      f"max={hist.get('staleness').max():.0f}")
print(f"IW variance: mean={np.nanmean(hist.get('iw_var')):.3e} "
      f"max={np.nanmax(hist.get('iw_var')):.3e}")
print(f"KL(learner||sampler): mean={np.nanmean(hist.get('kl')):.3e}")
print(f"reward: first10={hist.get('reward_mean')[:10].mean():.3f} "
      f"last10={hist.get('reward_mean')[-10:].mean():.3f}")
print(f"eval: {['%.3f' % e for e in rt.eval_scores]}")
print(f"sampler syncs: {[s.syncs for s in rt.samplers]}")
