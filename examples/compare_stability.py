"""The paper's Fig. 1/4 in miniature: run GRPO, GSPO and GEPO under the
same high-latency HeteroRL setting and print the stability comparison
(IW variance, gradient norms, best-to-last gap).

    PYTHONPATH=src python examples/compare_stability.py
"""
import numpy as np

from benchmarks.common import run_method

print(f"{'method':8s} {'eval_best':>9s} {'eval_last':>9s} {'gap':>7s} "
      f"{'iw_var':>10s} {'grad_std':>9s}")
for method in ("grpo", "gspo", "gepo"):
    rec = run_method(method, mode="hetero", max_delay=64,
                     delay_median_s=900.0, steps=30)
    print(f"{method:8s} {rec['eval_best']:9.3f} {rec['eval_last']:9.3f} "
          f"{rec['gap']:7.3f} {rec['iw_var_mean']:10.3e} "
          f"{rec['grad_norm_std']:9.3f}")
print("\nGEPO should show the smallest IW variance and best-to-last gap "
      "(paper Table 2: Δ=1.8 vs GSPO's 12.0).")
